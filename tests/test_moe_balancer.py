"""Reshape-on-MoE: balancer invariants + trainer integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.moe_balancer import (
    MoEBalancerConfig,
    MoEReshapeBalancer,
    shard_loads,
)
from repro.core.types import TransferMode
from repro.models import moe as moe_lib


def _skewed_moe(key, E=8, R=4, D=32, F=64, hot=0, boost=3.0):
    p = moe_lib.moe_init(key, D, F, E, n_replica_slots=R)
    p["router"] = p["router"].at[:, hot].add(boost)
    return p


class TestBalancerMechanics:
    def cfg(self, mode=TransferMode.SBR, R=4):
        return MoEBalancerConfig(n_experts=8, n_slots=8 + R, n_shards=4,
                                 mode=mode, min_steps_between=1)

    def run(self, mode, steps=24, R=4):
        cfg = self.cfg(mode, R)
        bal = MoEReshapeBalancer(cfg)
        p = _skewed_moe(jax.random.PRNGKey(0), R=R)
        spreads = []
        for step in range(steps):
            x = jax.random.normal(jax.random.PRNGKey(step), (256, 32))
            _, stats = moe_lib.moe_apply(
                p, x, top_k=2, capacity_factor=1.0,
                expert_routing=jnp.asarray(bal.state.expert_routing),
                return_stats=True)
            tps = np.asarray(stats["tokens_per_expert"])
            dem = np.asarray(stats["tokens_per_expert_router"])
            bal.observe(step, tps, dem)
            if bal.pending_copies:
                upd = bal.apply_pending(
                    {k: p[k] for k in ("w_gate", "w_up", "w_down")})
                p.update(upd)
            loads = shard_loads(bal.state, cfg)
            spreads.append(loads.max() / max(loads.mean(), 1e-9))
        return bal, spreads

    def test_sbr_replication_balances_shards(self):
        bal, spreads = self.run(TransferMode.SBR)
        # unmitigated spread (step 0) is ~2x fair; mitigation holds it well
        # below that for the rest of the run
        assert np.mean(spreads[-5:]) < 0.8 * spreads[0]
        assert any(e.kind == "sbr_replicate" for e in bal.state.events)
        # routing rows stay stochastic
        np.testing.assert_allclose(bal.state.expert_routing.sum(1), 1.0)

    def test_sbk_migration_balances_shards(self):
        bal, spreads = self.run(TransferMode.SBK, R=0)
        assert any(e.kind == "sbk_migrate" for e in bal.state.events)
        np.testing.assert_allclose(bal.state.expert_routing.sum(1), 1.0)
        # SBK keeps one-hot rows (whole-key moves only)
        assert set(np.unique(bal.state.expert_routing)) <= {0.0, 1.0}

    def test_replica_slots_tracked_and_merge_map(self):
        bal, _ = self.run(TransferMode.SBR)
        st = bal.state
        mm = bal.grad_merge_map()
        for slot, e in enumerate(st.slot_src):
            if e >= 0:
                assert st.slot_src[mm[slot]] == e     # maps to same expert
        # a replicated expert has >1 slot
        counts = np.bincount(st.slot_src[st.slot_src >= 0], minlength=8)
        assert counts.max() >= 2

    def test_migration_bytes_accounted(self):
        bal, _ = self.run(TransferMode.SBR)
        assert bal.state.bytes_migrated > 0

    def test_representativeness_improves(self):
        cfg = self.cfg()
        bal = MoEReshapeBalancer(cfg)
        p = _skewed_moe(jax.random.PRNGKey(0))
        reprs = []
        for step in range(24):
            x = jax.random.normal(jax.random.PRNGKey(step), (256, 32))
            _, stats = moe_lib.moe_apply(
                p, x, top_k=2, capacity_factor=1.0,
                expert_routing=jnp.asarray(bal.state.expert_routing),
                return_stats=True)
            tps = np.asarray(stats["tokens_per_expert"])
            dem = np.asarray(stats["tokens_per_expert_router"])
            reprs.append(bal.representativeness(tps, dem))
            bal.observe(step, tps, dem)
            if bal.pending_copies:
                p.update(bal.apply_pending(
                    {k: p[k] for k in ("w_gate", "w_up", "w_down")}))
        assert np.mean(reprs[-5:]) < np.mean(reprs[:3])


class TestMoEDataPlane:
    def test_identity_routing_matches_no_routing(self):
        key = jax.random.PRNGKey(0)
        p = moe_lib.moe_init(key, 32, 64, 8)
        x = jax.random.normal(key, (64, 32))
        eye = jnp.eye(8)
        a = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=2.0)
        b = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=2.0,
                              expert_routing=eye)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_replica_split_preserves_output(self):
        """Splitting a hot expert between two slots holding IDENTICAL
        weights must not change the layer output (record split is
        computation-invariant)."""
        key = jax.random.PRNGKey(0)
        E, R = 4, 1
        p = moe_lib.moe_init(key, 32, 64, E, n_replica_slots=R)
        # replica slot 4 holds a copy of expert 0's weights
        for n in ("w_gate", "w_up", "w_down"):
            p[n] = p[n].at[4].set(p[n][0])
        routing = jnp.eye(E, E + R)
        routing = routing.at[0, 0].set(0.5).at[0, 4].set(0.5)
        x = jax.random.normal(key, (64, 32))
        base = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=4.0)
        split = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=4.0,
                                  expert_routing=routing)
        np.testing.assert_allclose(np.asarray(split), np.asarray(base),
                                   atol=1e-5)

    def test_capacity_drops_tokens_on_hot_expert(self):
        key = jax.random.PRNGKey(0)
        p = _skewed_moe(key, R=0, boost=5.0)
        x = jax.random.normal(key, (256, 32))
        _, stats = moe_lib.moe_apply(p, x, top_k=2, capacity_factor=0.5,
                                     return_stats=True)
        assert float(stats["dropped_frac"]) > 0.05


class TestTrainerIntegration:
    def test_replica_grad_merge_equivalence(self):
        """Training with a replicated expert (grads merged + re-broadcast)
        must track training without replication."""
        from repro.train.trainer import broadcast_replicas, merge_replica_grads
        L, P = 2, 6
        mm = jnp.asarray(np.stack([[0, 1, 2, 3, 0, 5]] * L))  # slot4 -> 0
        g = jax.random.normal(jax.random.PRNGKey(0), (L, P, 4, 4))
        merged = merge_replica_grads(
            {"blocks": {"moe": {"w_gate": g, "w_up": g, "w_down": g}}},
            mm, L)
        mg = merged["blocks"]["moe"]["w_gate"]
        np.testing.assert_allclose(np.asarray(mg[:, 0]),
                                   np.asarray(g[:, 0] + g[:, 4]), atol=1e-6)
        # re-broadcast: replicas adopt primaries
        params = {"blocks": {"moe": {"w_gate": g, "w_up": g, "w_down": g}}}
        b = broadcast_replicas(params, mm)
        np.testing.assert_allclose(
            np.asarray(b["blocks"]["moe"]["w_gate"][:, 4]),
            np.asarray(g[:, 0]), atol=1e-6)

    def test_balancer_in_training_loop(self):
        from repro.configs import get_smoke
        from repro.train import TrainConfig, Trainer
        from repro.train.optimizer import AdamWConfig
        cfg = get_smoke("olmoe-1b-7b")
        tc = TrainConfig(
            opt=AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=40),
            remat=False,
            moe_balancer=MoEBalancerConfig(n_experts=8, n_slots=8,
                                           n_shards=4, min_steps_between=2))
        tr = Trainer(cfg, tc)
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (4, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        losses = [tr.train_step(batch)["loss"] for _ in range(6)]
        assert losses[-1] < losses[0]
        assert np.isfinite(losses).all()
