"""Resilience subsystem tests: incident log, retry/backoff, incremental
checksummed checkpoints, and the deterministic chaos harness.

The core invariant (ISSUE 8): under *any* injected fault schedule,
``Sink.series`` is bit-identical to the fault-free run on every plane —
reference, numpy, and device-jit (fused chains, armed DeviceController,
mid-MIGRATING mitigations) — with every recovery/demotion visible in
the incident log.
"""
import os

import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.core import ReshapeConfig
from repro.dataflow import checkpoint as ckpt
from repro.dataflow import resilience as rs
from repro.dataflow.engine import Engine, Source
from repro.dataflow.operators import Filter, GroupByAgg, Project, Sink

try:
    import jax  # noqa: F401
    HAS_JAX = True
except Exception:                                   # pragma: no cover
    HAS_JAX = False


def _series_equal(a, b):
    return (len(a) == len(b)
            and all(t1 == t2 and np.array_equal(c1, c2)
                    for (t1, c1), (t2, c2) in zip(a, b)))


def _zipf_stream(n, num_keys, seed=0, hot_frac=0.0):
    rng = np.random.default_rng(seed)
    keys = np.minimum(rng.zipf(1.3, n) - 1, num_keys - 1).astype(np.int64)
    if hot_frac:
        keys[rng.random(n) < hot_frac] = 0
    return keys, rng.uniform(0.0, 10.0, n)


def _pipeline(plane="numpy", *, n=3000, num_keys=24, num_workers=4,
              chunk=8, batch_ticks=4, controller=True, hot_frac=0.3,
              seed=0):
    """Source -> Filter -> GroupByAgg -> Sink on the requested plane
    (``reference`` | ``numpy`` | ``jit``), skewed stream, controller
    attached (armed in-dispatch on the jit plane)."""
    keys, vals = _zipf_stream(n, num_keys, seed, hot_frac)
    kw = dict(batch_ticks=batch_ticks)
    if plane == "reference":
        kw["reference"] = True
    elif plane == "jit":
        kw.update(partition_backend="pallas", device_executor="jit",
                  device_controller=True)
    eng = Engine(**kw)
    src = eng.add_source(Source("src", keys, vals, num_workers * chunk))
    filt = eng.add_op(Filter("filter", num_workers, num_workers * chunk,
                             predicate=lambda k, v: v >= 0))
    grp = eng.add_op(GroupByAgg("groupby", num_workers, chunk))
    sink = eng.add_op(Sink("sink", num_keys, snapshot_every=batch_ticks))
    eng.connect(src, filt, num_keys)
    eng.connect(filt, grp, num_keys)
    eng.connect(grp, sink, num_keys)
    ctrl = (eng.attach_controller(grp, ReshapeConfig(metric_period=4))
            if controller else None)
    return eng, sink, grp, ctrl


_BASELINE = {}


def _baseline_sink(plane):
    if plane not in _BASELINE:
        eng, sink, _, _ = _pipeline(plane)
        eng.run()
        _BASELINE[plane] = sink
    return _BASELINE[plane]


def _baseline_series(plane):
    return _baseline_sink(plane).series


# --------------------------------------------------------------------- #
# Incident log + retry policy units                                      #
# --------------------------------------------------------------------- #
class TestIncidentLog:
    def test_record_query_count_kinds(self):
        log = rs.IncidentLog()
        log.record("demotion", tick=3, edge="join", cause="probe fanout",
                   action="host path")
        log.record("retry", tick=4, edge="join", cause="chaos", attempt=1)
        log.record("retry", tick=4, edge="grp", cause="chaos", attempt=2)
        assert len(log) == 3
        assert log.count("retry") == 2
        assert log.count("retry", edge="join") == 1
        assert log.query(cause="fanout")[0].kind == "demotion"
        assert log.kinds() == {"demotion": 1, "retry": 2}
        assert [i.kind for i in log] == ["demotion", "retry", "retry"]
        log.clear()
        assert len(log) == 0

    def test_retry_policy_backoff(self):
        p = rs.RetryPolicy()                       # zero-delay default
        assert p.delay_s(1) == 0.0 and p.delay_s(3) == 0.0
        p = rs.RetryPolicy(base_delay_s=0.01, backoff=2.0,
                           max_delay_s=0.025)
        assert p.delay_s(1) == pytest.approx(0.01)
        assert p.delay_s(2) == pytest.approx(0.02)
        assert p.delay_s(3) == pytest.approx(0.025)    # capped

    def test_fault_plan_seeded_and_validated(self):
        a = rs.FaultPlan.from_seed(7, max_tick=50)
        b = rs.FaultPlan.from_seed(7, max_tick=50)
        assert a.events == b.events                 # replayable
        assert a.describe() == b.describe()
        with pytest.raises(ValueError):
            rs.FaultPlan([rs.FaultEvent("bogus", 1)])


# --------------------------------------------------------------------- #
# Hardened checkpointing                                                 #
# --------------------------------------------------------------------- #
class TestCheckpointing:
    def test_no_double_cut_at_tick_zero(self):
        """Satellite 1: one cut per grid boundary, counted honestly."""
        eng, sink, _, _ = _pipeline(controller=False)
        coord = ckpt.CheckpointCoordinator(eng, every_ticks=20)
        assert coord.checkpoints_taken == 1         # the initial cut
        assert coord.maybe_checkpoint() is None     # tick 0: no re-cut
        assert coord.checkpoints_taken == 1
        coord.run()
        ticks = [c.tick for c in coord.cuts]
        assert len(ticks) == len(set(ticks))        # never two per tick
        # init cut at 0 + one per grid boundary hit before completion
        assert coord.checkpoints_taken == 1 + (eng.tick - 1) // 20

    def test_incremental_matches_full_and_reuses(self):
        eng, sink, _, _ = _pipeline()
        inc = ckpt.CutBuilder(eng, incremental=True)
        full = ckpt.CutBuilder(eng, incremental=False)
        for _ in range(4):
            for _ in range(12):
                if eng.done():
                    break
                eng.run_tick()
            si, ci = inc.build()
            sf, cf = full.build()
            assert ci == cf == ckpt.compute_crc(si) == ckpt.compute_crc(sf)
        eng.run()                                   # drain: ops go idle
        si, ci = inc.build()
        sf, cf = full.build()
        assert ci == cf
        si2, ci2 = inc.build()                      # idle engine: all clean
        assert ci2 == ci
        assert inc.reused_ops > 0 and inc.reused_edges > 0
        assert full.reused_ops == 0 and full.reused_edges == 0

    def test_corrupted_cut_falls_back_to_previous(self):
        """Series comparison needs the canonical window schedule, so the
        coordinator polls at the engine's own window starts (forcing a
        seam onto a cut grid is not bit-identity-preserving)."""
        eng, sink, _, _ = _pipeline()
        ref = _baseline_series("numpy")
        coord = ckpt.CheckpointCoordinator(eng, every_ticks=16)

        def advance(until=None):
            while not eng.done() and (until is None or eng.tick < until):
                coord.maybe_checkpoint()
                eng.run_super_tick(eng._fusible_ticks(eng.batch_ticks))

        advance(until=40)
        assert len(coord.cuts) >= 2
        prev_tick = coord.cuts[-2].tick
        assert coord.corrupt_latest()
        cut = coord.recover()
        assert cut.tick == prev_tick                # fell back one cut
        assert coord.corrupt_detected == 1
        assert eng.incidents.count("checkpoint-corrupt") == 1
        assert eng.incidents.count("recovery") == 1
        advance()
        assert _series_equal(sink.series, ref)      # replay bit-identical

    def test_all_cuts_corrupt_raises(self):
        eng, _, _, _ = _pipeline(controller=False)
        coord = ckpt.CheckpointCoordinator(eng, every_ticks=16)
        for _ in range(20):
            coord.maybe_checkpoint()
            eng.run_tick()
        for c in coord.cuts:
            c.payload["state_units_moved"] = (
                float(c.payload["state_units_moved"]) + 1.0)
        with pytest.raises(rs.CheckpointError):
            coord.recover()

    def test_disk_persistence_retention_and_corrupt_file(self, tmp_path):
        store = str(tmp_path / "cuts")
        eng, sink, _, _ = _pipeline()
        coord = ckpt.CheckpointCoordinator(eng, every_ticks=16,
                                           retention=2, store=store)
        for _ in range(60):
            coord.maybe_checkpoint()
            eng.run_tick()
        files = sorted(os.listdir(store))
        assert len(files) == 2                      # retention bounds disk
        latest = ckpt.load_latest(store)
        assert latest.tick == coord.cuts[-1].tick
        # corrupt the newest file on disk: load_latest skips to previous
        with open(os.path.join(store, files[-1]), "r+b") as f:
            f.seek(12)
            b = f.read(1)
            f.seek(12)
            f.write(bytes([b[0] ^ 0xFF]))
        with pytest.raises(rs.CheckpointError):
            ckpt.load_cut(os.path.join(store, files[-1]))
        assert ckpt.load_latest(store).tick == coord.cuts[-2].tick

    def test_snapshot_isolation(self):
        """Satellite 2: no post-snapshot mutation can corrupt the cut."""
        eng, sink, grp, ctrl = _pipeline()
        for _ in range(30):
            eng.run_tick()
        snap = ckpt.snapshot(eng)
        crc0 = ckpt.compute_crc(snap)
        # mutate everything a cut copies: series rows, sink counts,
        # routing tables, worker state/queues, controller tracker/tau
        if sink.series:
            sink.series[-1][1][:] += 7
        sink.counts[:] += 1
        for e in eng.edges:
            e.routing.weights[:, 0] += 0.25
            e.routing._count[:] += 3
            e.tuples_sent += 5
        for w in grp.workers:
            for k in list(w.state.keys()):
                c, s = w.state[k]
                w.state[k] = (c + 1, s + 1.0)
                break
        if ctrl is not None:
            ctrl.tau += 123.0
            ctrl.tracker.phi[:] += 9.0
        eng.state_units_moved += 42.0
        assert ckpt.compute_crc(snap) == crc0       # the cut is an island

    @pytest.mark.skipif(not HAS_JAX, reason="jit plane needs jax")
    def test_restore_idempotency_device_plane(self):
        """Satellite 3: restore -> run k -> restore -> run k replays
        bit-identically on the jit plane, controller re-armed and fused
        chains re-formed."""
        ref = _baseline_series("jit")
        eng, sink, grp, ctrl = _pipeline("jit")
        for _ in range(6):
            eng.run_super_tick(eng._fusible_ticks(eng.batch_ticks))
        snap = ckpt.snapshot(eng)
        crc0 = ckpt.compute_crc(snap)

        def probe(k=4):
            out = []
            for _ in range(k):
                eng.run_super_tick(eng._fusible_ticks(eng.batch_ticks))
            out = [(t, c.copy()) for t, c in sink.series]
            return out, eng.tick

        s1, t1 = probe()
        ckpt.restore(eng, snap)
        assert ckpt.compute_crc(snap) == crc0       # restore reads only
        s2, t2 = probe()
        assert t1 == t2 and _series_equal(s1, s2)   # bit-identical replay
        ckpt.restore(eng, snap)
        eng.run()
        assert _series_equal(sink.series, ref)
        # the in-dispatch controller re-armed across the restores
        assert grp.device is None or grp.device.ctrl is not None


# --------------------------------------------------------------------- #
# Retry / backoff + structured incidents on the device plane             #
# --------------------------------------------------------------------- #
class _AlwaysFail:
    def dispatch_fault(self, runtime):
        raise rs.InjectedDispatchFault("chaos: injected failure")


@pytest.mark.skipif(not HAS_JAX, reason="device plane needs jax")
class TestDeviceRetry:
    def test_transient_dispatch_fault_retries_in_place(self):
        ref = _baseline_series("jit")
        eng, sink, _, _ = _pipeline("jit")
        plan = rs.FaultPlan([rs.FaultEvent(rs.DISPATCH_FAIL, 12, count=2)])
        runner = rs.ChaosRunner(eng, plan, every_ticks=20)
        runner.run()
        assert _series_equal(sink.series, ref)
        assert eng.incidents.count("retry") == 2    # healed by retrying
        assert eng.incidents.count("demotion") == 0
        assert runner.injected[rs.DISPATCH_FAIL] == 1

    def test_exhausted_retries_demote_drain_first(self):
        ref = _baseline_series("jit")
        eng, sink, _, _ = _pipeline("jit")
        burst = eng.retry_policy.max_attempts + 1   # one edge exhausts
        plan = rs.FaultPlan([rs.FaultEvent(rs.DISPATCH_FAIL, 12,
                                           count=burst)])
        runner = rs.ChaosRunner(eng, plan, every_ticks=20)
        runner.run()
        assert _series_equal(sink.series, ref)      # demotion is bit-exact
        demos = eng.incidents.query("demotion",
                                    cause="dispatch retries exhausted")
        assert len(demos) == 1
        assert eng.incidents.count("retry") == eng.retry_policy.max_attempts

    def test_controller_dispatch_exhaustion_deactivates(self):
        eng, sink, grp, ctrl = _pipeline("jit")
        for _ in range(6):
            eng.run_super_tick(eng._fusible_ticks(eng.batch_ticks))
        dev = grp.device
        assert dev is not None and dev.ctrl is not None and dev.ctrl.active
        assert not dev.ctrl._chaos_dispatch_ok(_AlwaysFail())
        assert not dev.ctrl.active
        demo = eng.incidents.query("ctrl-demotion",
                                   cause="dispatch retries exhausted")
        assert len(demo) == 1 and demo[0].edge == "groupby"
        assert (eng.incidents.count("retry", edge="groupby")
                == eng.retry_policy.max_attempts)
        eng.run()                                   # host stepping resumes
        assert eng.done()
        # A controller demotion legitimately changes the canonical
        # window schedule (the armed controller lifts the metric-grid
        # clamp from ``_fusible_ticks``), so the snapshot *timeline*
        # need not match the armed baseline — but the final aggregate
        # totals are schedule-invariant: no record is lost or doubled.
        ref = _baseline_sink("jit")
        np.testing.assert_array_equal(sink.counts, ref.counts)
        np.testing.assert_allclose(sink.sums, ref.sums, rtol=0, atol=1e-9)


# --------------------------------------------------------------------- #
# Satellite 6: one-time warning sites also log structured incidents      #
# --------------------------------------------------------------------- #
class TestIncidentSites:
    def test_radix_cliff_records_global_incident_once(self):
        from repro.dataflow import exchange as ex
        saved = ex._WARNED_WIDE_FALLBACK
        saved_log = list(rs.GLOBAL.incidents)
        try:
            ex._WARNED_WIDE_FALLBACK = False
            rs.GLOBAL.clear()
            wide = ex.MAX_RADIX_WORKERS + 1
            dest = np.array([wide - 1, 0, wide - 1], dtype=np.int64)
            hist = np.zeros(wide, dtype=np.int64)
            hist[0], hist[wide - 1] = 1, 2
            with pytest.warns(RuntimeWarning, match="radix-sort limit"):
                ex.scatter_order(dest, hist)
            ex.scatter_order(dest, hist)            # second call: silent
            hits = rs.GLOBAL.query("radix-cliff")
            assert len(hits) == 1                   # exactly once
            assert str(wide) in hits[0].cause
        finally:
            ex._WARNED_WIDE_FALLBACK = saved
            rs.GLOBAL.incidents[:] = saved_log

    @pytest.mark.skipif(not HAS_JAX, reason="device plane needs jax")
    def test_untraceable_fn_demotion_and_chain_fallback(self):
        """An impure project fn fails the fused chain dispatch (one
        chain head), then the per-edge first dispatch: both sites log
        exactly one incident."""
        keys, vals = _zipf_stream(2000, 16)
        eng = Engine(partition_backend="pallas", device_executor="jit",
                     batch_ticks=4)
        src = eng.add_source(Source("src", keys, vals, 32))
        proj = eng.add_op(Project("proj", 4, 32,
                                  fn=lambda k, v: (k, np.asarray(v) * 2.0),
                                  preserves_keys=True))
        grp = eng.add_op(GroupByAgg("groupby", 4, 8))
        sink = eng.add_op(Sink("sink", 16, snapshot_every=4))
        for a, b in zip([src, proj, grp], [proj, grp, sink]):
            eng.connect(a, b, 16)
        with pytest.warns(RuntimeWarning):
            eng.run()
        falls = eng.incidents.query("chain-fallback")
        assert len(falls) == 1 and falls[0].edge == "proj"
        demos = eng.incidents.query("demotion", cause="untraceable fn")
        assert len(demos) == 1 and demos[0].edge == "proj"

    @pytest.mark.skipif(not HAS_JAX, reason="device plane needs jax")
    def test_probe_fanout_demotion_incident(self):
        from repro.dataflow import device as dev
        from repro.dataflow.workflows import build_w1
        saved = dev.MAX_EMIT_CELLS
        try:
            dev.MAX_EMIT_CELLS = 32                 # force the ceiling
            wf = build_w1(scale=0.02, num_workers=4, batch_ticks=4,
                          partition_backend="pallas",
                          device_executor="jit", strategy="none")
            wf.run()
            hits = wf.engine.incidents.query("demotion",
                                             cause="probe fanout")
            assert len(hits) == 1 and hits[0].edge == "join"
        finally:
            dev.MAX_EMIT_CELLS = saved

    @pytest.mark.skipif(not HAS_JAX, reason="device plane needs jax")
    def test_controller_mismatch_arbitration_incident(self):
        eng, sink, grp, ctrl = _pipeline("jit")
        dev = grp.device
        while not eng.done() and not (dev.ctrl is not None
                                      and dev.ctrl.active and dev.ctrl.meta):
            eng.run_super_tick(eng._fusible_ticks(eng.batch_ticks))
        assert dev.ctrl.meta, "controller never ran an in-dispatch round"
        dev.ctrl.cstate = dict(dev.ctrl.cstate,
                               weights=dev.ctrl.cstate["weights"] + 1.0)
        with pytest.warns(RuntimeWarning, match="host wins"):
            dev.ctrl.drain()
        hits = eng.incidents.query("ctrl-mismatch")
        assert len(hits) == 1 and hits[0].edge == "groupby"
        assert "host wins" in hits[0].action


# --------------------------------------------------------------------- #
# The chaos harness: directed per-fault-kind coverage                    #
# --------------------------------------------------------------------- #
def _chaos_identical(plane, events, *, every_ticks=16, retention=4):
    ref = _baseline_series(plane)
    eng, sink, grp, ctrl = _pipeline(plane)
    runner = rs.ChaosRunner(eng, rs.FaultPlan(events),
                            every_ticks=every_ticks, retention=retention)
    runner.run()
    assert _series_equal(sink.series, ref), (
        f"series diverged under {rs.FaultPlan(events).describe()} "
        f"on the {plane} plane")
    return eng, runner


class TestChaosDirected:
    @pytest.mark.parametrize("plane", ["reference", "numpy"])
    def test_worker_loss(self, plane):
        eng, runner = _chaos_identical(
            plane, [rs.FaultEvent(rs.WORKER_LOSS, 21, target=1)])
        assert runner.injected[rs.WORKER_LOSS] == 1
        assert eng.incidents.count("recovery") == 1
        assert eng.incidents.count("chaos-recover") == 1

    def test_straggler_throttle(self):
        eng, runner = _chaos_identical(
            "numpy", [rs.FaultEvent(rs.STRAGGLER, 10, duration=6)])
        assert runner.injected[rs.STRAGGLER] == 1
        assert eng.incidents.count("recovery") == 1

    def test_corrupt_cut_recovers_from_previous(self):
        eng, runner = _chaos_identical(
            "numpy", [rs.FaultEvent(rs.CORRUPT_CUT, 40)])
        assert runner.injected[rs.CORRUPT_CUT] == 1
        assert eng.incidents.count("checkpoint-corrupt") == 1
        assert eng.incidents.count("recovery") == 1

    def test_missing_cut(self):
        eng, runner = _chaos_identical(
            "numpy", [rs.FaultEvent(rs.MISSING_CUT, 40)])
        assert eng.incidents.count("recovery") == 1

    def test_ctrl_drop_and_delay(self):
        eng, runner = _chaos_identical(
            "numpy", [rs.FaultEvent(rs.CTRL_DROP, 9, duration=4),
                      rs.FaultEvent(rs.CTRL_DELAY, 33, duration=3)])
        assert runner.recovered == 2
        assert eng.incidents.count("recovery") == 2

    @pytest.mark.skipif(not HAS_JAX, reason="jit plane needs jax")
    def test_worker_loss_mid_mitigation_armed_controller(self):
        """Acceptance: a worker loss while a mitigation is in flight on
        an armed device-controller edge still replays bit-identically."""
        # probe the clean run for a tick with an active mitigation
        eng, sink, grp, ctrl = _pipeline("jit")
        mit_tick = None
        while not eng.done():
            eng.run_super_tick(eng._fusible_ticks(eng.batch_ticks))
            from repro.core.types import MitigationPhase
            if any(m.phase is not MitigationPhase.IDLE
                   for m in ctrl.mitigations.values()):
                mit_tick = eng.tick
                break
        assert mit_tick is not None, "no mitigation fired on the probe run"
        eng2, runner = _chaos_identical(
            "jit", [rs.FaultEvent(rs.WORKER_LOSS, mit_tick + 1, target=1)])
        assert runner.injected[rs.WORKER_LOSS] == 1
        assert eng2.incidents.count("recovery") == 1

    @pytest.mark.skipif(not HAS_JAX, reason="jit plane needs jax")
    def test_dispatch_fail_on_jit_plane(self):
        eng, runner = _chaos_identical(
            "jit", [rs.FaultEvent(rs.DISPATCH_FAIL, 12, count=1)])
        assert eng.incidents.count("retry") == 1


# --------------------------------------------------------------------- #
# The propcheck property (ISSUE 8 acceptance)                            #
# --------------------------------------------------------------------- #
class TestChaosProperty:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_any_fault_schedule_is_bit_identical(self, seed):
        """Under ANY seeded fault schedule, ``Sink.series`` equals the
        fault-free run, on a plane rotated by the seed (reference /
        numpy / jit), and every rollback is visible in the log."""
        planes = ["reference", "numpy"] + (["jit"] if HAS_JAX else [])
        plane = planes[seed % len(planes)]
        ref = _baseline_series(plane)
        eng, sink, grp, ctrl = _pipeline(plane)
        plan = rs.FaultPlan.from_seed(seed, max_tick=70)
        runner = rs.ChaosRunner(eng, plan, every_ticks=16)
        runner.run()
        assert _series_equal(sink.series, ref), (
            f"seed={seed} plane={plane} plan={plan.describe()}")
        # Every rollback-healed fault leaves a "recovery" incident;
        # dispatch-fail heals by retry and mem-pressure in place (the
        # spill tier absorbs the squeeze), so neither rolls back.
        rollbacks = sum(runner.injected[k] for k in runner.injected
                        if k not in (rs.DISPATCH_FAIL, rs.MEM_PRESSURE))
        assert eng.incidents.count("recovery") == rollbacks
        assert eng.incidents.count("fault") == sum(
            runner.injected.values())
        assert eng.chaos is None                    # runner detached
