"""Sharding-rule structure tests (no multi-device lowering here — that is
the dry-run's job; these verify pspec pytrees match param pytrees)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke
sharding = pytest.importorskip(
    "repro.dist.sharding", reason="repro.dist not present in this build")
from repro.models import init_params
from repro.train import optimizer


def _single_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_pspecs_match_param_tree(arch):
    """Spec pytree must zip exactly with the param pytree (full config
    shapes via eval_shape — no allocation)."""
    cfg = get_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = sharding.param_pspecs(cfg, mesh)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    # tree_map raises on structure mismatch
    merged = jax.tree.map(lambda sds, sp: (sds.shape, sp), shapes, specs,
                          is_leaf=lambda x: isinstance(x, (P,)) or hasattr(x, "shape"))
    assert jax.tree_util.tree_structure(shapes) is not None
    for sds, sp in jax.tree.leaves(merged, is_leaf=lambda x: isinstance(x, tuple)
                                   and len(x) == 2 and isinstance(x[1], P)):
        pass  # structure zip succeeded


@pytest.mark.parametrize("arch", ["granite-8b", "olmoe-1b-7b",
                                  "deepseek-v2-lite-16b", "hymba-1.5b"])
def test_sharded_dims_divisible(arch):
    """Every sharded dim must divide by the production mesh axis size."""
    cfg = get_config(arch)
    mesh_shape = {"data": 16, "model": 16}

    class FakeMesh:
        shape = mesh_shape
    specs = sharding.param_pspecs(cfg, FakeMesh())
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))

    def check(sds, spec):
        if not isinstance(spec, P):
            return
        for dim, ax in zip(sds.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([mesh_shape[a] for a in axes]))
            assert dim % total == 0, (arch, sds.shape, spec)

    jax.tree.map(check, shapes, specs,
                 is_leaf=lambda x: isinstance(x, P))


def test_zero1_opt_specs_add_data_axis():
    cfg = get_config("granite-8b")
    mesh_shape = {"data": 16, "model": 16}

    class FakeMesh:
        shape = mesh_shape
    pspec = sharding.param_pspecs(cfg, FakeMesh())
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    ospec = sharding.opt_pspecs(pspec, shapes, FakeMesh())
    # embed [vocab, d]: params ("model", None) -> opt ("model", "data")
    assert tuple(ospec["embed"]) == ("model", "data")


def test_cache_pspecs_structure_matches_cache():
    from repro.models import init_cache
    for arch in ("yi-6b", "deepseek-v2-lite-16b", "rwkv6-1.6b", "hymba-1.5b"):
        cfg = get_config(arch)

        class FakeMesh:
            shape = {"data": 16, "model": 16}
        spec = sharding.cache_pspecs(cfg, SHAPES["decode_32k"], FakeMesh())
        sds = jax.eval_shape(lambda: init_cache(cfg, 128, 1024))
        jax.tree.map(lambda a, b: None, sds, spec,
                     is_leaf=lambda x: isinstance(x, P))  # structure zip


def test_batch_pspecs_shard_batch_over_dp():
    cfg = get_config("granite-8b")

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    b = sharding.batch_pspecs(cfg, SHAPES["train_4k"], FakeMesh())
    assert b["tokens"] == P(("data",), None)

    class PodMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    b = sharding.batch_pspecs(cfg, SHAPES["train_4k"], PodMesh())
    assert b["tokens"] == P(("pod", "data"), None)


def test_hlo_analyzer_counts_trip_counts():
    """The roofline analyzer multiplies while bodies by known_trip_count."""
    from repro.launch.hlo_analysis import analyze_text
    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %a = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] constant(1)
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    res = analyze_text(hlo)
    # dot: 2*8*8*8 = 1024 flops x 10 trips
    assert res["flops"] == pytest.approx(10 * 1024)


def test_all_cells_enumeration():
    from repro.configs import all_cells
    cells = all_cells()
    # 10 archs x 4 shapes - 8 long_500k skips = 32
    assert len(cells) == 32
    assert ("rwkv6-1.6b", "long_500k") in cells
    assert ("granite-8b", "long_500k") not in cells
