"""Spill-tier tests (out-of-core memory tiering of the device plane).

Core invariant: with spill forced at tiny watermarks — a budget small
enough that every edge spills repeatedly — ``Sink.series`` is
bit-identical to the unspilled run on every plane (numpy / device-jit
with fused chains and an armed DeviceController), including checkpoint
fail/recover mid-spill, and memory pressure surfaces as structured
``mem-pressure`` incidents consumed by the attached controller.
"""
import os

import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.core import ReshapeConfig
from repro.dataflow import resilience as rs
from repro.dataflow import spill as sp
from repro.dataflow.engine import Engine, Source
from repro.dataflow.operators import Filter, GroupByAgg, Sink
from repro.dataflow.workflows import build_w1, build_w3

try:
    import jax  # noqa: F401
    HAS_JAX = True
except Exception:                                   # pragma: no cover
    HAS_JAX = False

needs_jax = pytest.mark.skipif(not HAS_JAX, reason="jit plane needs jax")


def _series_equal(a, b):
    return (len(a) == len(b)
            and all(t1 == t2 and np.array_equal(c1, c2)
                    for (t1, c1), (t2, c2) in zip(a, b)))


# --------------------------------------------------------------------- #
# Units: config, segments, state                                         #
# --------------------------------------------------------------------- #
class TestSpillUnits:
    def test_config_validation(self):
        with pytest.raises(ValueError):
            sp.SpillConfig(budget_cells=0)
        with pytest.raises(ValueError):
            sp.SpillConfig(budget_cells=64, low_wm=0.9, high_wm=0.5)
        cfg = sp.SpillConfig(budget_cells=100)
        assert cfg.per_worker(4) == 25
        assert cfg.per_worker(1000) == 8          # functional floor

    def test_resolve_budget(self, monkeypatch):
        monkeypatch.delenv("REPRO_DEVICE_BUDGET", raising=False)
        assert sp.resolve_budget(None) is None
        assert sp.resolve_budget(64).budget_cells == 64
        cfg = sp.SpillConfig(budget_cells=32, high_wm=0.9, low_wm=0.1)
        assert sp.resolve_budget(cfg) is cfg
        monkeypatch.setenv("REPRO_DEVICE_BUDGET", "128")
        assert sp.resolve_budget(None).budget_cells == 128

    def test_segment_roundtrip_and_crc(self):
        k = np.arange(10, dtype=np.int64)
        v = np.linspace(0, 1, 10)
        seg = sp.SpillSegment((k, v), 10)
        assert seg.verify()
        assert np.array_equal(seg.arrays[0], k)
        seg.corrupt()
        assert not seg.verify()

    def test_state_ordering_and_prefetch(self):
        cfg = sp.SpillConfig(budget_cells=64)
        st_ = sp.SpillState(cfg, 2)
        a = sp.SpillSegment((np.array([1, 2], np.int64),), 2)
        b = sp.SpillSegment((np.array([3], np.int64),), 1)
        c = sp.SpillSegment((np.array([4], np.int64),), 1)
        st_.prepend_ring(0, b)       # eviction: newest resident -> front
        st_.prepend_ring(0, a)       # older eviction goes in front of it
        st_.append_ring(0, c)        # fresh overflow -> back
        assert st_.ring_len(0) == 4 and st_.any()
        st_.prefetch(0, lambda x: x)      # identity "upload"
        seg, dev = st_.pop_ring_front(0)
        assert seg is a and dev is not None       # prefetch hit
        assert st_.prefetch_hits == 1
        assert [s.n for s in st_.rings[0]] == [1, 1]
        st_.clear()
        assert not st_.any()

    def test_corrupt_one_and_drain_raises(self):
        cfg = sp.SpillConfig(budget_cells=64)
        st_ = sp.SpillState(cfg, 1)
        st_.append_rows(0, sp.SpillSegment(
            (np.arange(4, dtype=np.int64),), 4))
        assert st_.corrupt_one()
        with pytest.raises(sp.SpillCorruptError):
            st_.drain_rows(0)


# --------------------------------------------------------------------- #
# The acceptance workflow (ISSUE 10): W3 build state >= 4x the budget    #
# --------------------------------------------------------------------- #
@needs_jax
class TestAcceptance:
    def _run(self, budget=None, sanitize=False, **kw):
        env = dict(os.environ)
        if sanitize:
            os.environ["REPRO_SANITIZE"] = "1"
        try:
            wf = build_w3(strategy="reshape", partition_backend="pallas",
                          device_executor="jit", device_controller=True,
                          device_budget=budget, **kw)
            wf.run()
        finally:
            os.environ.clear()
            os.environ.update(env)
        return wf

    def test_w3_4x_over_budget_stays_on_jit_plane(self):
        # W3's sort row store holds all 40_000 rows; a 10_000-cell
        # budget is exceeded >= 4x, and the rings spill on top of that.
        ref = self._run()
        wf = self._run(budget=10_000, sanitize=True)
        inc = wf.engine.incidents
        assert inc.count("demotion") == 0, inc.kinds()
        assert inc.count("mem-pressure") >= 1
        assert _series_equal(wf.sink.series, ref.sink.series)
        assert wf.controllers[0].pressure_consumed >= 1
        assert wf.controllers[0].pressure_events == []
        # the device plane stayed armed end to end on every edge
        for e in wf.engine.edges:
            assert not (e.device_plane or "").startswith("demoted")

    def test_w1_probe_with_budget_bit_identical(self):
        ref = build_w1(strategy="none", scale=0.05,
                       partition_backend="pallas", device_executor="jit")
        ref.run()
        env = dict(os.environ)
        os.environ["REPRO_SANITIZE"] = "1"
        try:
            wf = build_w1(strategy="none", scale=0.05,
                          partition_backend="pallas", device_executor="jit",
                          device_budget=256)
            wf.run()
        finally:
            os.environ.clear()
            os.environ.update(env)
        assert wf.engine.incidents.count("demotion") == 0
        assert _series_equal(wf.sink.series, ref.sink.series)


# --------------------------------------------------------------------- #
# Propcheck invariance: tiny watermarks, every plane, chaos mid-spill    #
# --------------------------------------------------------------------- #
def _pipeline(plane="numpy", *, budget=None, n=3000, num_keys=24,
              num_workers=4, chunk=8, batch_ticks=4, hot_frac=0.3,
              seed=0):
    rng = np.random.default_rng(seed)
    keys = np.minimum(rng.zipf(1.3, n) - 1, num_keys - 1).astype(np.int64)
    if hot_frac:
        keys[rng.random(n) < hot_frac] = 0
    vals = rng.uniform(0.0, 10.0, n)
    kw = dict(batch_ticks=batch_ticks)
    if plane == "jit":
        kw.update(partition_backend="pallas", device_executor="jit",
                  device_controller=True, device_budget=budget)
    eng = Engine(**kw)
    src = eng.add_source(Source("src", keys, vals, num_workers * chunk))
    filt = eng.add_op(Filter("filter", num_workers, num_workers * chunk,
                             predicate=lambda k, v: v >= 0))
    grp = eng.add_op(GroupByAgg("groupby", num_workers, chunk))
    sink = eng.add_op(Sink("sink", num_keys, snapshot_every=batch_ticks))
    eng.connect(src, filt, num_keys)
    eng.connect(filt, grp, num_keys)
    eng.connect(grp, sink, num_keys)
    ctrl = eng.attach_controller(grp, ReshapeConfig(metric_period=4))
    return eng, sink, ctrl


_REF = {}


def _ref_series(seed, plane="jit"):
    """The unspilled baseline, per plane: snapshot timelines are only
    comparable within one plane (the armed device controller lifts the
    metric-grid clamp, so jit and numpy partition windows differently)."""
    if (plane, seed) not in _REF:
        eng, sink, _ = _pipeline(plane, budget=None, seed=seed)
        eng.run()
        _REF[(plane, seed)] = sink.series
    return _REF[(plane, seed)]


@needs_jax
class TestSpillInvariance:
    def test_budget_is_inert_on_the_numpy_plane(self, monkeypatch):
        """No device runtimes -> the env budget changes nothing."""
        ref = _ref_series(0, "numpy")
        monkeypatch.setenv("REPRO_DEVICE_BUDGET", "48")
        eng, sink, _ = _pipeline("numpy", seed=0)
        eng.run()
        assert _series_equal(sink.series, ref)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_tiny_budget_bit_identical(self, seed):
        """Any tiny budget (every edge spills repeatedly), any stream
        seed: the jit plane with fused chains and an armed controller
        matches its own unspilled run bit-exactly."""
        stream = seed % 3
        budget = [48, 64, 96, 128][seed % 4]
        ref = _ref_series(stream)
        eng, sink, _ = _pipeline("jit", budget=budget, seed=stream)
        eng.run()
        assert _series_equal(sink.series, ref), (
            f"seed={seed} budget={budget}")
        assert eng.incidents.count("demotion") == 0

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_chaos_mid_spill_bit_identical(self, seed):
        """Checkpoint fail/recover (and the rest of the taxonomy,
        including the new kinds) while the spill tier is active."""
        ref = _ref_series(0)
        eng, sink, _ = _pipeline("jit", budget=64, seed=0)
        plan = rs.FaultPlan.from_seed(seed, max_tick=70)
        runner = rs.ChaosRunner(eng, plan, every_ticks=16)
        runner.run()
        assert _series_equal(sink.series, ref), (
            f"seed={seed} plan={plan.describe()}")


# --------------------------------------------------------------------- #
# Directed chaos: the two new fault kinds                                #
# --------------------------------------------------------------------- #
@needs_jax
class TestChaosKinds:
    def test_mem_pressure_budget_shrink(self):
        """A mid-run budget shrink forces spill; healed in place (undo
        only, no rollback), results bit-identical."""
        ref = _ref_series(0)
        eng, sink, _ = _pipeline("jit", budget=None, seed=0)
        runner = rs.ChaosRunner(
            eng, rs.FaultPlan([rs.FaultEvent(rs.MEM_PRESSURE, 20,
                                             duration=12, target=1)]),
            every_ticks=16)          # target=1: the groupby runtime
        runner.run()
        assert _series_equal(sink.series, ref)
        assert runner.injected[rs.MEM_PRESSURE] == 1
        assert eng.incidents.count("fault", cause="mem-pressure") == 1
        assert eng.incidents.count("mem-pressure") >= 1   # spill engaged
        assert eng.incidents.count("recovery") == 0       # no rollback
        # undo restored the unbounded budget
        assert all(o.device is None or o.device.budget_cfg is None
                   for o in eng.ops)

    def test_spill_corrupt_recovers_from_cut(self):
        """A CRC-corrupted spill segment is discarded by rollback to the
        last valid cut; results bit-identical."""
        ref = _ref_series(0)
        eng, sink, _ = _pipeline("jit", budget=48, seed=0)
        runner = rs.ChaosRunner(
            eng, rs.FaultPlan([rs.FaultEvent(rs.SPILL_CORRUPT, 40)]),
            every_ticks=8)
        runner.run()
        assert _series_equal(sink.series, ref)
        assert runner.injected[rs.SPILL_CORRUPT] == 1
        assert eng.incidents.count("recovery") == 1
        inc = eng.incidents.query("fault", cause="spill-corrupt")
        assert len(inc) == 1

    def test_crc_failure_raises_and_records(self):
        """Direct CRC-failure path: a poisoned segment read back at a
        sync boundary raises and records a spill-corrupt incident."""
        eng, sink, _ = _pipeline("jit", budget=48, seed=0)
        corrupted = False
        while not eng.done():
            eng.run_super_tick(1)
            for o in eng.ops:
                rt = o.device
                if (not corrupted and rt is not None
                        and rt.spill is not None and rt.spill.corrupt_one()):
                    corrupted = True
                    with pytest.raises(sp.SpillCorruptError):
                        while not eng.done():      # hits refill/sync soon
                            eng.run_super_tick(1)
                            for o2 in eng.ops:
                                if o2.device is not None:
                                    o2.device.sync_host()
                    assert eng.incidents.count("spill-corrupt") >= 1
                    return
        pytest.fail("no spill segment ever existed to corrupt")


# --------------------------------------------------------------------- #
# Degradation paths: regrow cap, chunked probe emission                  #
# --------------------------------------------------------------------- #
@needs_jax
class TestDegradation:
    def test_regrow_capped_incident_once(self):
        """Ring regrowth past the budget-implied cap (a single burst
        bigger than the budget itself) surfaces one structured
        ``regrow-capped`` incident — and still grows, correctness over
        the budget."""
        num_keys = 8
        rng = np.random.default_rng(1)
        keys = rng.integers(0, num_keys, 64).astype(np.int64)
        vals = rng.uniform(0, 1, 64)
        eng = Engine(partition_backend="pallas", device_executor="jit",
                     device_budget=sp.SpillConfig(budget_cells=16))
        src = eng.add_source(Source("src", keys, vals, 8))
        grp = eng.add_op(GroupByAgg("groupby", 2, 1))
        sink = eng.add_op(Sink("sink", num_keys))
        eng.connect(src, grp, num_keys)
        eng.connect(grp, sink, num_keys)
        eng.run_super_tick(1)          # small first burst -> small cap
        for n_burst in (600, 1200):    # bursts way past the budget cap
            k = rng.integers(0, num_keys, n_burst).astype(np.int64)
            src.out_edge.send((k, rng.uniform(0, 1, n_burst)))
            eng.run_super_tick(1)
        assert eng.incidents.count("regrow-capped") == 1   # one-time

    def test_probe_cliff_becomes_chunked_emission(self, monkeypatch):
        """With a budget configured, a probe whose padded emit buffer
        would blow MAX_EMIT_CELLS emits in sub-budget chunks
        (``degraded-emit``) instead of demoting — bit-identical."""
        from repro.dataflow import device as dev
        ref = build_w1(strategy="none", scale=0.02,
                       partition_backend="pallas", device_executor="jit")
        ref.run()
        monkeypatch.setattr(dev, "MAX_EMIT_CELLS", 1 << 7)
        wf = build_w1(strategy="none", scale=0.02,
                      partition_backend="pallas", device_executor="jit",
                      device_budget=100_000)
        wf.run()
        inc = wf.engine.incidents
        assert inc.count("degraded-emit") == 1
        assert inc.count("demotion", cause="probe fanout") == 0
        assert _series_equal(wf.sink.series, ref.sink.series)


# --------------------------------------------------------------------- #
# Sanitizer: the spill cross-check                                       #
# --------------------------------------------------------------------- #
@needs_jax
class TestSanitizeSpill:
    def test_forked_spill_mirror_trips(self, monkeypatch):
        from repro.analysis.sanitize import SanitizeError
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        eng, sink, _ = _pipeline("jit", budget=48, seed=0)
        forked = False
        with pytest.raises(SanitizeError):
            while not eng.done():
                eng.run_super_tick(1)
                for o in eng.ops:
                    rt = o.device
                    if (not forked and rt is not None
                            and rt.spilled_lens.sum() > 0):
                        rt.spilled_lens[0] += 1        # fork the mirror
                        forked = True
                    if forked and rt is not None:
                        rt.sync_host()
        assert forked
        assert eng.incidents.count("sanitize-spill") >= 1
