"""Trainer, optimizer, checkpointing, compression, data pipeline, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import PipelineConfig, SkewAwarePipeline, zipf_doc_lengths
compression = pytest.importorskip(
    "repro.dist.compression", reason="repro.dist not present in this build")
from repro.models import init_params
from repro.serve import Request, ServeEngine
from repro.train import TrainConfig, Trainer, checkpoint as ckpt, optimizer

KEY = jax.random.PRNGKey(0)


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = optimizer.AdamWConfig(lr=0.1, weight_decay=0.0,
                                    warmup_steps=0, total_steps=100)
        params = {"w": jnp.ones((4,)) * 5.0}
        state = optimizer.init(params)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}
            params, state = optimizer.update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_schedule_warmup_and_cosine(self):
        cfg = optimizer.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                    min_lr_frac=0.1)
        assert float(optimizer.schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(optimizer.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(optimizer.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)

    def test_grad_clip(self):
        g = {"a": jnp.ones((100,)) * 10}
        clipped, gn = optimizer.clip_by_global_norm(g, 1.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


class TestTrainerLoop:
    def test_loss_decreases_dense(self):
        cfg = get_smoke("llama3.2-3b")
        tr = Trainer(cfg, TrainConfig(
            opt=optimizer.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50),
            remat=False))
        toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        losses = [tr.train_step(batch)["loss"] for _ in range(10)]
        assert losses[-1] < losses[0] - 0.5

    def test_grad_compression_error_feedback(self):
        cfg = get_smoke("yi-6b")
        tr = Trainer(cfg, TrainConfig(
            opt=optimizer.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50),
            remat=False, grad_compression=True))
        toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        losses = [tr.train_step(batch)["loss"] for _ in range(10)]
        assert losses[-1] < losses[0] - 0.3       # still converges

    def test_compression_unbiased_over_time(self):
        g = {"w": jax.random.normal(KEY, (256,)) * 1e-3}
        err = compression.init_error(g)
        total_deq = jnp.zeros((256,))
        n = 40
        for _ in range(n):
            deq, err = compression.compress_tree(g, err)
            total_deq += deq["w"]
        # error feedback: cumulative dequantized ~= cumulative true grads
        np.testing.assert_allclose(np.asarray(total_deq / n),
                                   np.asarray(g["w"]), atol=2e-5)


class TestCheckpoint:
    def test_roundtrip_and_latest(self):
        cfg = get_smoke("granite-8b")
        params = init_params(cfg, KEY)
        state = optimizer.init(params)
        tree = {"params": params, "opt": state}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 3, tree, {"arch": cfg.name})
            ckpt.save(d, 7, tree, {"arch": cfg.name})
            path, meta = ckpt.latest(d)
            assert meta["step"] == 7
            restored = ckpt.restore(path, tree)
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32))

    def test_atomicity_no_partial_files(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"x": jnp.ones(3)})
            files = os.listdir(d)
            assert not [f for f in files if f.endswith(".tmp")]

    def test_prune_keeps_newest(self):
        with tempfile.TemporaryDirectory() as d:
            for s in range(6):
                ckpt.save(d, s, {"x": jnp.ones(2)})
            ckpt.prune(d, keep=2)
            path, meta = ckpt.latest(d)
            assert meta["step"] == 5
            npzs = [f for f in os.listdir(d) if f.endswith(".npz")]
            assert len(npzs) == 2

    def test_elastic_restore_respects_new_sharding(self):
        """Restore onto a different device layout (elastic restart)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        with tempfile.TemporaryDirectory() as d:
            p = ckpt.save(d, 1, tree)
            sh = {"w": NamedSharding(mesh, P("data", None))}
            restored = ckpt.restore(p, tree, shardings=sh)
            assert restored["w"].sharding == sh["w"]
            np.testing.assert_allclose(np.asarray(restored["w"]),
                                       np.asarray(tree["w"]))

    def test_trainer_resume_equivalence(self):
        """train k steps == train j, checkpoint, restore, train k-j."""
        cfg = get_smoke("llama3.2-3b")
        def make():
            return Trainer(cfg, TrainConfig(
                opt=optimizer.AdamWConfig(lr=1e-3, warmup_steps=2,
                                          total_steps=50), remat=False))
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        a = make()
        for _ in range(6):
            la = a.train_step(batch)["loss"]
        b = make()
        for _ in range(3):
            b.train_step(batch)
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 3, {"params": b.params, "opt": b.opt_state})
            path, _ = ckpt.latest(d)
            tree = ckpt.restore(path, {"params": b.params, "opt": b.opt_state})
        c = make()
        c.params, c.opt_state = tree["params"], tree["opt"]
        for _ in range(3):
            lc = c.train_step(batch)["loss"]
        assert lc == pytest.approx(la, rel=1e-4)


class TestDataPipeline:
    def test_skew_aware_beats_static(self):
        lengths = zipf_doc_lengths(800, 512, seed=3)
        def run(eta):
            pl = SkewAwarePipeline(PipelineConfig(
                n_shards=8, seq_len=512, eta_tokens=eta, tau_tokens=1024))
            for i in range(0, 800, 80):
                pl.ingest(lengths[i:i + 80])
            return pl
        balanced = run(eta=2048.0)
        static = run(eta=1e18)        # threshold never reached
        assert balanced.rebalances > 0 and static.rebalances == 0
        assert balanced.padding_skew() <= static.padding_skew()

    def test_batches_cover_all_tokens(self):
        pl = SkewAwarePipeline(PipelineConfig(n_shards=4, seq_len=128,
                                              batch_per_shard=2))
        lens = zipf_doc_lengths(100, 128, seed=1)
        pl.ingest(lens)
        total = 0
        while (b := pl.next_batch()) is not None:
            total += int(b["mask"].sum())
        assert total == int(lens.sum())


class TestServe:
    @pytest.mark.parametrize("arch", ["whisper-medium", "internvl2-2b",
                                      "hymba-1.5b", "olmoe-1b-7b"])
    def test_serve_stub_frontends_and_states(self, arch):
        """Serving works for enc-dec (frame stub), VLM (patch-prefix
        prefill), hybrid (SSM state) and MoE (drop-free decode)."""
        cfg = get_smoke(arch)
        params = init_params(cfg, KEY)
        eng = ServeEngine(params, cfg, batch_size=2, max_len=6, eos_id=-1)
        for i in range(2):
            eng.submit(Request(uid=i, prompt=np.arange(2 + i, dtype=np.int32),
                               max_new_tokens=3))
        done = eng.run()
        assert len(done) == 2
        assert all(len(r.out_tokens) == 3 for r in done)

    def test_requests_complete_and_are_deterministic(self):
        cfg = get_smoke("yi-6b")
        params = init_params(cfg, KEY)
        def run():
            eng = ServeEngine(params, cfg, batch_size=2, max_len=8, eos_id=-1)
            for i in range(3):
                eng.submit(Request(uid=i, prompt=np.arange(2 + i,
                                                           dtype=np.int32),
                                   max_new_tokens=4))
            done = eng.run()
            return {r.uid: r.out_tokens for r in done}
        a, b = run(), run()
        assert len(a) == 3
        assert a == b
        assert all(len(v) == 4 for v in a.values())
