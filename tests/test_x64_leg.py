"""``JAX_ENABLE_X64=1`` tier-1 leg.

The device plane wraps its own allocations in the ``_x64()`` context and
pins every constructor dtype (rule ``dtype-drift``), so flipping the
*global* x64 mode must change nothing: kernels stay bit-identical and
the fused jit plane still matches the numpy host plane.  This leg runs
the kernel suite plus a device-plane slice in a subprocess with
``JAX_ENABLE_X64=1`` — the mode is process-wide and must not leak into
the main pytest process.
"""
import os
import subprocess
import sys

import pytest

pytest.importorskip("jax")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: device-plane slice: full-W1 equivalence, the basic fold pipeline and
#: chain fusion cover every step builder without re-running the 2-minute
#: file under both modes.
DEVICE_SUBSET = [
    "tests/test_device_plane.py::TestJitPlaneEquivalence"
    "::test_fold_pipeline_bit_identical",
    "tests/test_device_plane.py::TestJitPlaneEquivalence"
    "::test_w1_full_device_plane_matches_numpy",
    "tests/test_device_plane.py::TestChainFusion"
    "::test_chain_bit_identical_and_placements_drop",
]


def _run_x64(targets, timeout=900):
    env = dict(os.environ, JAX_ENABLE_X64="1",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x", *targets],
        capture_output=True, text=True, env=env, cwd=REPO,
        timeout=timeout)


def test_kernels_under_x64():
    r = _run_x64(["tests/test_kernels.py"])
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]


def test_device_plane_subset_under_x64():
    r = _run_x64(DEVICE_SUBSET)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
